"""End-to-end driver: train a deformable-conv classifier (~reduced VGG19-3)
for a few hundred steps on synthetic blob images, with checkpoints.

  PYTHONPATH=src python examples/train_dcn.py --steps 300

The deformable layers train their own offsets (stage-1 conv weights are
zero-initialized = regular grid, then learn to deform). Loss should fall
well below ln(4)=1.386 chance level.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.data import DataConfig, image_batch
from repro.models.dcn_models import DcnNetConfig, dcn_net_apply, init_dcn_net
from repro.optim import AdamWConfig, adamw_update, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--variant", default="dcn2", choices=["dcn1", "dcn2"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_dcn_ckpt")
    args = ap.parse_args()

    cfg = DcnNetConfig(name="vgg19", n_deform=3, variant=args.variant,
                       img_size=32, width_mult=0.25,
                       num_classes=args.classes)
    params = init_dcn_net(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20,
                          total_steps=args.steps, weight_decay=0.01)
    opt_state = init_opt_state(params, opt_cfg)
    dcfg = DataConfig(seed=0, global_batch=args.batch)

    @jax.jit
    def step(params, opt_state, images, labels):
        def loss_fn(p):
            logits = dcn_net_apply(p, cfg, images)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, labels[:, None], 1)[:, 0]
            return jnp.mean(lse - gold)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = adamw_update(params, grads, opt_state,
                                            opt_cfg)
        return params, opt_state, loss

    ckptr = ckpt.AsyncCheckpointer(args.ckpt_dir)
    t0 = time.time()
    first = None
    for s in range(args.steps):
        b = image_batch(dcfg, s, img=32, classes=args.classes)
        params, opt_state, loss = step(params, opt_state,
                                       jnp.asarray(b["images"]),
                                       jnp.asarray(b["labels"]))
        if first is None:
            first = float(loss)
        if s % 25 == 0 or s == args.steps - 1:
            print(f"step {s:4d} loss {float(loss):.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
        if (s + 1) % 100 == 0:
            ckptr.save(s + 1, {"params": params, "opt": opt_state})
    ckptr.wait()
    print(f"done: loss {first:.3f} -> {float(loss):.3f} "
          f"(chance={jnp.log(args.classes):.3f}); "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
