"""Train any assigned --arch (reduced config) with the production trainer:
data pipeline -> sharded jit step -> async checkpoints -> resume.

  PYTHONPATH=src python examples/train_lm.py --arch jamba-v0.1-52b --steps 60
"""

import argparse

import jax.numpy as jnp

from repro import configs
from repro.configs.base import ShapeCell
from repro.launch.mesh import make_host_mesh
from repro.launch.train import train_loop
from repro.optim import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=configs.ARCHS)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=True)
    shape = ShapeCell("example", "train", args.seq, args.batch)
    mesh = make_host_mesh(1, 1)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps)
    _, _, losses = train_loop(cfg, shape, mesh, steps=args.steps,
                              opt_cfg=opt, ckpt_dir=args.ckpt_dir,
                              param_dtype=jnp.float32)
    print(f"[{args.arch}] loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{args.steps} steps")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
