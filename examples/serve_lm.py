"""Serve a small model with batched requests through the decode engine
(continuous batching over fixed cache slots).

  PYTHONPATH=src python examples/serve_lm.py --arch gemma2-27b --requests 8
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main()
