"""Quickstart: the paper's deformable convolution, end to end.

  PYTHONPATH=src python examples/quickstart.py

1. builds a deformable conv (Eq. 1-3) and runs the XLA reference path,
2. runs the SAME layer through the fused Pallas kernel (BLI-as-matmul on
   the MXU, interpret=True on CPU) and checks they agree,
3. builds the Tile Dependency Table from the layer's real offsets, runs
   Algorithm 1, and prints the DRAM-traffic win over the naive order,
4. runs a small DCN network through the network-graph executor
   (backend="graph") and prints the per-group fused-vs-unfused DRAM
   bytes — the paper's Fig. 18 layer-fusion delta, executed.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (deformable_conv2d, init_deformable_conv,
                        make_square_grid, per_pixel_input_tiles,
                        schedule_tiles, simulate_network,
                        simulate_strategies, tdt_from_coords)
from repro.core.deform import conv2d, offsets_to_coords
from repro.kernels.ops import deformable_conv2d_pallas
from repro.models.dcn_models import DcnNetConfig, dcn_net_apply, init_dcn_net
from repro.runtime import GraphConfig, build_graph, run_graph
from repro.runtime.fused_exec import network_sim_specs


def main():
    key = jax.random.PRNGKey(0)
    c_in, c_out, hw = 32, 64, 24

    # 1. deformable conv, XLA reference path
    params = init_deformable_conv(key, c_in, c_out, variant="dcn2")
    params = params._replace(w_off=jax.random.normal(
        jax.random.fold_in(key, 1), params.w_off.shape) * 0.3)
    x = jax.random.normal(jax.random.fold_in(key, 2), (1, hw, hw, c_in))
    y_ref = deformable_conv2d(x, params)
    print(f"XLA path:    {x.shape} -> {y_ref.shape}")

    # 2. fused Pallas kernel (stages 2+3 in one VMEM-resident kernel)
    y_pal = deformable_conv2d_pallas(x, params)
    np.testing.assert_allclose(y_pal, y_ref, rtol=2e-4, atol=2e-4)
    print("Pallas path: matches XLA reference (rtol 2e-4)")

    # 3. TDT + Algorithm 1 over the layer's actual sampling pattern
    offsets = conv2d(x, params.w_off, params.b_off)
    coords = offsets_to_coords(offsets.astype(jnp.float32), 3, "dcn2")[0]
    grid = make_square_grid(hw, hw, 4)
    B = np.asarray(tdt_from_coords(coords, grid, grid))
    pp = np.asarray(per_pixel_input_tiles(coords, grid))
    rep = simulate_strategies(B, pp, grid, channels=c_in, c_out=c_out,
                              kernel_size=3, buffer_bytes=4096)
    sched = schedule_tiles(B, 4)
    print(f"TDT: {B.shape[0]} output tiles x {B.shape[1]} input tiles, "
          f"density {B.mean():.2f}")
    print(f"tile loads  naive={rep['naive'].tile_loads}  "
          f"bitvec={rep['bitvec'].tile_loads}  "
          f"Alg1={rep['scheduled'].tile_loads}")
    print(f"Alg 1 execution order (first 8 tiles): {sched.oid[:8]}")

    # 4. network-graph executor: cross-layer tile fusion (backend="graph")
    cfg = DcnNetConfig(name="vgg19", n_deform=2, img_size=16,
                       width_mult=0.125, num_classes=4)
    net_params = init_dcn_net(jax.random.fold_in(key, 3), cfg)
    imgs = jax.random.normal(jax.random.fold_in(key, 4), (1, 16, 16, 3))
    logits = dcn_net_apply(net_params, cfg, imgs, backend="graph",
                           graph=GraphConfig(tile=4))
    print(f"graph backend: {imgs.shape} -> logits {logits.shape}")

    graph = build_graph(cfg)
    _, trace = run_graph(net_params["convs"], graph, imgs,
                         config=GraphConfig(tile=4), return_trace=True)
    specs = network_sim_specs(trace)
    fused = simulate_network(specs, boundary_bytes=trace.boundary_bytes)
    unfused = simulate_network(specs, boundary_bytes=trace.boundary_bytes,
                               fused=False)
    for g_f, g_u in zip(fused.groups, unfused.groups):
        if g_f.n_layers > 1:
            print(f"  fused group ({g_f.n_layers} layers): "
                  f"{g_f.total_dram_bytes} B fused vs "
                  f"{g_u.total_dram_bytes} B per-layer")
    saved = 100 * (1 - fused.total_dram_bytes / unfused.total_dram_bytes)
    print(f"network DRAM: fused={fused.total_dram_bytes} B, "
          f"per-layer={unfused.total_dram_bytes} B ({saved:.1f}% saved)")


if __name__ == "__main__":
    main()
